"""PartitionStore residency semantics (core/store.py) + GraphSession
serving behaviour (core/session.py).

Covers the ISSUE-2 satellite/acceptance list:
  * LRU eviction order and hit/miss/eviction accounting,
  * prefetch staging byte-identical device buffers to a cold load,
  * OPAT answers unchanged under cache capacities 1, 2, and k,
  * GraphSession.submit == fresh per-query engine run for all 3 engines,
  * a repeated OPAT query on a warm session: >= 1 cache hit and strictly
    fewer cold transfers than its first run.
"""
import json

import numpy as np
import pytest

from repro.core import (EngineConfig, MAX_SN, GraphSession, LoadStats, OPATEngine, PartitionStore,
                        TraditionalMPEngine, build_catalog, build_partitions, generate_plan,
                        match_query, partition_graph)
from repro.data.generators import subgen_like_graph, subgen_queries


@pytest.fixture(scope="module")
def setup():
    g = subgen_like_graph(n_nodes=250, n_edges=700, n_embed=10, seed=3)
    assign = partition_graph(g, 4, "kway_shem")
    pg = build_partitions(g, assign, 4, scheme="kway_shem")
    cat = build_catalog(g)
    queries = [dq.disjuncts[0] for dq in subgen_queries(g)]
    dqueries = subgen_queries(g)
    return g, pg, cat, queries, dqueries


# ---------------------------------------------------------------------------
# PartitionStore unit behaviour
# ---------------------------------------------------------------------------

def test_cold_then_warm_accounting(setup):
    g, pg, cat, queries, _ = setup
    store = PartitionStore(pg)
    assert store.stats.cold_loads == 0 and store.stats.warm_loads == 0
    e0 = store.get(0)
    assert store.stats.misses == 1 and store.stats.hits == 0
    assert store.stats.bytes_cold == e0.nbytes > 0
    e0b = store.get(0)
    assert store.stats.misses == 1 and store.stats.hits == 1
    # a warm load returns the SAME committed device buffers, not a copy
    assert e0b.part["node_gid"] is e0.part["node_gid"]
    assert store.stats.hit_rate == 0.5


def test_pin_blocks_lru_eviction(setup):
    """A pinned entry survives over-capacity staging (the double-buffer
    case: evaluate pid while the runner-up's H2D copy lands), and unpin
    restores the capacity invariant by evicting LRU-first."""
    g, pg, cat, queries, _ = setup
    store = PartitionStore(pg, capacity_parts=1)
    store.get(0)
    store.pin(0)
    store.get(1)                        # stages the runner-up: transient 2
    assert sorted(store.resident_keys()) == [0, 1]
    assert store.stats.evictions == 0
    store.unpin(0)                      # capacity re-enforced: LRU (0) goes
    assert store.resident_keys() == [1]
    assert store.stats.evictions == 1
    # the evaluated partition left; the runner-up is already warm
    m0 = store.stats.misses
    store.get(1)
    assert store.stats.misses == m0


def test_pin_refcounts_and_context_manager(setup):
    g, pg, cat, queries, _ = setup
    store = PartitionStore(pg, capacity_parts=1)
    store.get(0)
    store.pin(0)
    with store.pinned(0):               # refcount 2
        store.get(1)
        assert sorted(store.resident_keys()) == [0, 1]
    # context exit dropped one ref; the outer pin still protects 0
    assert sorted(store.resident_keys()) == [0, 1]
    store.unpin(0)
    assert len(store.resident_keys()) == 1


def test_pin_does_not_block_explicit_drop(setup):
    """Pins only guard the implicit LRU path — drop/release/clear are
    explicit owner decisions and still remove pinned entries."""
    g, pg, cat, queries, _ = setup
    store = PartitionStore(pg)
    store.get(2)
    with store.pinned(2):
        assert store.drop(2) is True
        assert not store.contains(2)
    store.get(2)                        # re-stages cold, no stale pin state
    assert store.contains(2)


def test_pinned_answers_unchanged_under_capacity_one(setup):
    """OPAT with prefetch + capacity 1: the double-buffered loop (pin
    current, prefetch runner-up) stays oracle-identical."""
    g, pg, cat, queries, _ = setup
    for q in queries:
        plan = generate_plan(q, g, cat)
        store = PartitionStore(pg, capacity_parts=1)
        eng = OPATEngine(pg, EngineConfig(cap=16384), store=store)
        res = eng.run(plan, MAX_SN, seed=1)
        ref = match_query(g, q, q_pad=8)
        assert np.array_equal(np.unique(res.answers, axis=0), ref), q.name
        assert not store._pins            # every pin released


def test_lru_eviction_order(setup):
    g, pg, cat, queries, _ = setup
    store = PartitionStore(pg, capacity_parts=2)
    store.get(0)
    store.get(1)
    assert sorted(store.resident_keys()) == [0, 1]
    store.get(0)              # refresh 0 -> LRU order is now [1, 0]
    store.get(2)              # must evict 1 (least recently used), not 0
    assert sorted(store.resident_keys()) == [0, 2]
    assert store.stats.evictions == 1
    store.get(3)              # evicts 0
    assert sorted(store.resident_keys()) == [2, 3]
    assert store.stats.evictions == 2
    # re-touching an evicted partition is a cold load again
    m0 = store.stats.misses
    store.get(1)
    assert store.stats.misses == m0 + 1


def test_capacity_one_never_holds_two(setup):
    g, pg, cat, queries, _ = setup
    store = PartitionStore(pg, capacity_parts=1)
    for pid in (0, 1, 2, 3, 0):
        store.get(pid)
        assert len(store.resident_keys()) == 1
    assert store.stats.misses == 5 and store.stats.evictions == 4


def test_capacity_bytes_evicts(setup):
    g, pg, cat, queries, _ = setup
    one = PartitionStore(pg).get(0).nbytes
    # room for ~1.5 partitions -> second get must evict the first
    store = PartitionStore(pg, capacity_bytes=int(1.5 * one))
    store.get(0)
    store.get(1)
    assert store.resident_keys() == [1]
    assert store.stats.evictions == 1


def test_prefetch_byte_identical_to_cold_load(setup):
    g, pg, cat, queries, _ = setup
    cold = PartitionStore(pg)
    warm = PartitionStore(pg)
    ref = cold.get(2)                       # demand (cold) load
    assert warm.prefetch(2) is True
    got = warm.get(2)                       # served by the prefetched entry
    assert warm.stats.misses == 0 and warm.stats.hits == 1
    assert warm.stats.prefetch_issued == 1 and warm.stats.prefetch_hits == 1
    assert warm.stats.bytes_cold == 0
    assert warm.stats.bytes_prefetched == ref.nbytes
    for k in ref.part:  # byte-identical (NaN-safe) buffer comparison
        assert np.asarray(ref.part[k]).tobytes() == np.asarray(got.part[k]).tobytes(), k
    assert np.asarray(ref.g2l).tobytes() == np.asarray(got.g2l).tobytes()
    # prefetching a resident entry is a no-op, and a second get is a plain
    # hit (prefetch_hits counts first touches only)
    assert warm.prefetch(2) is False
    warm.get(2)
    assert warm.stats.prefetch_issued == 1 and warm.stats.prefetch_hits == 1


def test_stacked_entries_and_sharding_keys(setup):
    g, pg, cat, queries, _ = setup
    store = PartitionStore(pg)
    e = store.get_stacked((1, 0, 1))
    assert e.part["node_gid"].shape[0] == 3 and e.g2l.shape[0] == 3
    assert np.array_equal(np.asarray(e.part["pid"]), np.asarray([1, 0, 1]))
    store.get_stacked((1, 0, 1))
    assert store.stats.hits == 1            # same tuple -> warm
    store.get_stacked((0, 1, 1))
    assert store.stats.misses == 2          # order matters -> distinct entry
    # a stacked entry of n partitions costs n against capacity_parts
    small = PartitionStore(pg, capacity_parts=2)
    small.get(0)
    small.get_stacked((1, 2))
    assert small.resident_keys() == [(1, 2)]
    assert small.stats.evictions == 1


def test_stacked_entry_count_is_bounded(setup):
    """Even an otherwise-unbounded store caps distinct stacked tuples
    (each duplicates its partitions' buffers): LRU beyond the cap."""
    g, pg, cat, queries, _ = setup
    store = PartitionStore(pg, max_stacked_entries=2)
    store.get(0)                       # singles are not affected by the cap
    store.get_stacked((0, 1))
    store.get_stacked((1, 2))
    store.get_stacked((2, 3))          # evicts (0, 1), the LRU tuple
    keys = store.resident_keys()
    assert 0 in keys and (0, 1) not in keys
    assert (1, 2) in keys and (2, 3) in keys
    assert store.stats.evictions == 1
    with pytest.raises(ValueError):
        PartitionStore(pg, max_stacked_entries=0)


def test_contains_and_drop_match_sharded_stagings(setup):
    """contains()/drop() must see entries staged WITH a sharding (cached
    under a (key, sharding) composite) — MapReduceMP's all-partitions
    bundle must be releasable through the public API."""
    import jax
    from jax.sharding import SingleDeviceSharding
    g, pg, cat, queries, _ = setup
    store = PartitionStore(pg)
    sh = SingleDeviceSharding(jax.devices()[0])
    store.get_stacked((0, 1), sharding=sh)
    assert store.contains((0, 1))
    assert store.drop((0, 1)) is True
    assert not store.contains((0, 1))
    assert store.drop((0, 1)) is False


@pytest.mark.parametrize("p", [2, 4])
def test_traditional_mp_lane_order_is_canonical(setup, p):
    """Permutations of the same top-p set reuse one stacked entry — the
    staged tuple must be permutation-invariant in the chosen set even when
    under-full iterations pad lanes (p=4 over <4 eligible partitions
    exercises the padding path), and stay oracle-exact."""
    g, pg, cat, queries, _ = setup
    store = PartitionStore(pg)
    eng = TraditionalMPEngine(pg, p, EngineConfig(cap=16384), store=store)
    for seed in (1, 2):    # vary heuristic tie-break order
        for q in queries:
            plan = generate_plan(q, g, cat)
            res = eng.run(plan, MAX_SN, seed=seed)
            assert np.array_equal(np.unique(res.answers, axis=0),
                                  match_query(g, q, q_pad=8)), q.name
            for it in res.partitions_per_iteration:
                assert len(it) <= p
    # every stacked key is in canonical form: the distinct pids sorted,
    # with padding lanes replicating the smallest pid — so the same chosen
    # set always maps to the same key, whatever order the heuristic
    # returned it in
    for k in store.resident_keys():
        if isinstance(k, tuple):
            distinct = sorted(set(k))
            expect = sorted(distinct + [distinct[0]] * (len(k) - len(distinct)))
            assert list(k) == expect, k
    assert store.stats.hits > 0        # recurring sets actually warm


def test_load_stats_delta_and_validation(setup):
    g, pg, cat, queries, _ = setup
    a = LoadStats(hits=5, misses=3, evictions=1)
    b = LoadStats(hits=2, misses=3)
    d = a - b
    assert d.hits == 3 and d.misses == 0 and d.evictions == 1
    with pytest.raises(ValueError):
        PartitionStore(pg, capacity_parts=0)
    with pytest.raises(ValueError):
        PartitionStore(pg).get_stacked(())


def test_load_stats_arithmetic_is_field_complete():
    """Satellite (ISSUE-5): __add__/__sub__/to_dict cover EVERY counter
    field via dataclasses.fields — including the disk-tier counters
    (disk_reads / read_ahead_hits & co.) — so a future field cannot
    silently drop out of delta/sum accounting."""
    import dataclasses as dc
    fields = [f.name for f in dc.fields(LoadStats)]
    # the disk tier's headline counters exist and default to zero
    for name in ("disk_reads", "read_ahead_issued", "read_ahead_hits",
                 "bytes_disk", "host_evictions"):
        assert name in fields
    a = LoadStats(**{f: 3 * i + 1 for i, f in enumerate(fields)})
    b = LoadStats(**{f: i for i, f in enumerate(fields)})
    add, sub = a + b, a - b
    for i, f in enumerate(fields):
        assert getattr(add, f) == (3 * i + 1) + i, f
        assert getattr(sub, f) == (3 * i + 1) - i, f
    d = a.to_dict()
    for f in fields:
        assert d[f] == getattr(a, f), f
    # derived keys ride along without displacing any raw field
    assert d["cold_loads"] == a.misses and d["warm_loads"] == a.hits
    assert 0.0 <= d["hit_rate"] <= 1.0
    # a zero-initialized LoadStats is the identity for both operations
    zero = LoadStats()
    assert (a + zero) == a and (a - zero) == a


@pytest.mark.parametrize("capacity", [1, 2, 4])
def test_opat_answers_unchanged_under_tiny_cache(setup, capacity):
    """Eviction affects transfers, never correctness: capacities 1, 2, k."""
    g, pg, cat, queries, _ = setup
    eng = OPATEngine(pg, EngineConfig(cap=16384),
                     store=PartitionStore(pg, capacity_parts=capacity))
    for q in queries:
        plan = generate_plan(q, g, cat)
        res = eng.run(plan, MAX_SN, seed=1)
        assert np.array_equal(np.unique(res.answers, axis=0),
                              match_query(g, q, q_pad=8)), (q.name, capacity)


def test_run_stats_carry_scheme_and_residency(setup):
    """Satellite: the real scheme name (not '?') + cold/warm accounting in
    every engine's RunStats."""
    g, pg, cat, queries, _ = setup
    opat = OPATEngine(pg, EngineConfig(cap=16384))
    trad = TraditionalMPEngine(pg, 2, EngineConfig(cap=16384))
    plan = generate_plan(queries[0], g, cat)
    for eng in (opat, trad):
        st = eng.run(plan, MAX_SN, seed=1).stats
        assert st.scheme == "kway_shem"
        assert st.cold_loads is not None and st.cold_loads > 0
        assert st.warm_loads is not None and st.prefetch_hits is not None


# ---------------------------------------------------------------------------
# GraphSession serving API
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine_name", ["opat", "traditional", "mapreduce"])
def test_session_submit_matches_fresh_engine(setup, engine_name):
    """Acceptance: GraphSession.submit returns answers identical to a fresh
    per-query engine run, for every engine and with/without a budget."""
    g, pg, cat, queries, _ = setup
    k = 1 if engine_name == "mapreduce" else 4   # 1 partition per device
    sess = GraphSession(g, k=k, scheme="kway_shem", engine=engine_name,
                        seed=1, processors=2, config=EngineConfig(cap=32768))
    for q in queries:
        got = sess.submit(q)
        ref = match_query(g, q, q_pad=8)
        assert np.array_equal(got.answers, ref), (engine_name, q.name)
        assert got.n_answers == ref.shape[0]
        # budgeted submit: min(K, total) unique real answers
        rep = sess.submit(q, max_answers=2)
        refset = {tuple(r) for r in ref}
        assert rep.n_answers == min(2, ref.shape[0])
        assert all(tuple(r) in refset for r in rep.answers)


def test_session_warm_repeat_has_hits_and_fewer_cold_loads(setup):
    """Acceptance: a repeated OPAT query on a warm session reports >= 1
    cache hit and strictly fewer cold transfers than its first run."""
    g, pg, cat, queries, _ = setup
    sess = GraphSession(g, k=4, scheme="kway_shem", engine="opat", seed=1)
    q = next(q for q in queries if match_query(g, q, q_pad=8).shape[0] > 0)
    first = sess.submit(q)
    assert first.load_stats.cold_loads > 0     # cold session really transfers
    second = sess.submit(q)
    assert np.array_equal(first.answers, second.answers)
    assert second.load_stats.hits >= 1
    assert second.load_stats.cold_loads < first.load_stats.cold_loads
    # the per-run RunStats agree with the session-level delta
    st = second.reports[0].stats
    assert st.warm_loads >= 1
    assert st.cold_loads == second.load_stats.cold_loads


def test_session_disjunctive_union_and_latency(setup):
    g, pg, cat, queries, dqueries = setup
    from repro.core.oracle import match_disjunctive
    sess = GraphSession(g, k=4, scheme="kway_shem", engine="opat", seed=1)
    for dq in dqueries:
        res = sess.submit(dq)
        ref = match_disjunctive(g, dq, q_pad=8)
        assert np.array_equal(res.answers, ref), dq.name
        assert len(res.reports) == len(dq.disjuncts)
        assert res.latency_s >= 0.0
        assert res.n_loads == sum(s.n_loads for s in res.stats)


def test_session_workload_profile_accumulates_and_persists(setup, tmp_path):
    g, pg, cat, queries, dqueries = setup
    sess = GraphSession(g, k=4, scheme="kway_shem", engine="opat", seed=1)
    for dq in dqueries:
        sess.submit(dq)
    prof = sess.workload_profile()
    assert prof["queries_served"] == len(dqueries)
    assert prof["scheme"] == "kway_shem" and prof["k"] == 4
    assert len(prof["partitions"]) == 4
    total_loads = sum(p["loads"] for p in prof["partitions"])
    assert total_loads > 0
    for p in prof["partitions"]:
        assert 0.0 <= p["completion_rate"] <= 1.0
    # every OPAT partition load is exactly one store get: cold + warm adds up
    assert prof["cache"]["cold_loads"] + prof["cache"]["warm_loads"] == total_loads
    path = tmp_path / "profile.json"
    sess.save_profile(str(path))
    assert json.loads(path.read_text())["queries_served"] == len(dqueries)


def test_session_heuristic_override_and_validation(setup):
    g, pg, cat, queries, _ = setup
    with pytest.raises(ValueError):
        GraphSession(g, engine="nope")
    with pytest.raises(ValueError):
        GraphSession(None)
    sess = GraphSession(g, k=4, scheme="kway_shem", engine="opat", seed=1)
    q = queries[0]
    ref = match_query(g, q, q_pad=8)
    for h in ("max-sn", "min-sn", "max-yield"):
        res = sess.submit(q, heuristic=h)
        assert np.array_equal(res.answers, ref), h
        assert all(s.heuristic == h for s in res.stats)


def test_session_from_prebuilt_pg(setup):
    """A session can adopt an existing PartitionedGraph + catalog."""
    g, pg, cat, queries, _ = setup
    sess = GraphSession(pg=pg, engine="opat", seed=1, catalog=cat)
    assert sess.scheme == "kway_shem" and sess.k == 4
    q = queries[0]
    assert np.array_equal(sess.submit(q).answers, match_query(g, q, q_pad=8))


def test_session_cache_capacity_bounds_residency(setup):
    g, pg, cat, queries, _ = setup
    sess = GraphSession(g, k=4, scheme="kway_shem", engine="opat", seed=1,
                        cache_parts=1)
    for q in queries:
        sess.submit(q)
    assert len(sess.store.resident_keys()) == 1
    assert sess.load_stats.evictions > 0
