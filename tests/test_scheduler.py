"""QueryScheduler — shared-load multi-query serving (core/scheduler.py).

Covers the ISSUE-4 satellite/acceptance list:
  * batched answers bit-identical to sequential ``submit`` for the same
    query set, for all three engines;
  * per-query ``max_answers`` budgets respected inside a shared batch;
  * retirement releases partitions from the index, with store eviction /
    release observable via ``LoadStats``;
  * shared serving of overlapping queries pays strictly fewer cold loads
    than isolated (no-sharing) serving;
  * ``QueryResult.load_stats`` deltas are round-scoped (a query's counters
    cover exactly the loads it participated in, never other queries');
  * the workload JSONL round trip (serve ``--workload`` format);
  * the shared-vs-isolated throughput sweep (slow marker).
"""
import json

import numpy as np
import pytest

from repro.core import (EngineConfig, GraphSession, MAX_SN, MAX_YIELD_SHARED,
                        batch_bucket, match_disjunctive,
                        rank_partitions_shared)
from repro.core.query import DisjunctiveQuery
from repro.data.generators import subgen_like_graph, subgen_queries


@pytest.fixture(scope="module")
def setup():
    g = subgen_like_graph(n_nodes=250, n_edges=700, n_embed=10, seed=3)
    dqueries = subgen_queries(g)
    refs = {dq.name: match_disjunctive(g, dq, q_pad=8) for dq in dqueries}
    return g, dqueries, refs


def make_session(g, engine="opat", k=4, **kw):
    return GraphSession(g, k=k, scheme="kway_shem", engine=engine, seed=1,
                        processors=2, config=EngineConfig(cap=32768), **kw)


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------

def test_batch_bucket_powers_of_two():
    assert [batch_bucket(n) for n in (1, 2, 3, 4, 5, 8, 9, 16)] == \
        [1, 2, 4, 4, 8, 8, 16, 16]


def test_rank_partitions_shared_scoring():
    rng = np.random.default_rng(0)
    # pid 0: two waiters with high SNI but near-zero completion rates;
    # pid 1: one waiter with modest SNI but perfect completion rate
    waiting = {0: [(10, 0.01), (10, 0.01)], 1: [(5, 1.0)]}
    assert rank_partitions_shared(MAX_SN, waiting, rng)[0] == 0      # 20 > 5
    assert rank_partitions_shared(MAX_YIELD_SHARED, waiting, rng)[0] == 1
    assert rank_partitions_shared(MAX_SN, {}, rng) == []
    with pytest.raises(ValueError):
        rank_partitions_shared("min-sn", waiting, rng)


def test_rank_partitions_shared_aggregates_over_waiters():
    rng = np.random.default_rng(0)
    # one query alone would prefer pid 1 (bigger single SNI), but the
    # workload's summed yield makes pid 0 the shared winner
    waiting = {0: [(4, 0.5), (4, 0.5), (4, 0.5)], 1: [(5, 0.5)]}
    assert rank_partitions_shared(MAX_YIELD_SHARED, waiting, rng)[0] == 0


# ---------------------------------------------------------------------------
# parity with sequential submit (acceptance)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine_name", ["opat", "traditional", "mapreduce"])
def test_submit_many_matches_sequential_submit(setup, engine_name):
    """Acceptance: batched answers bit-identical to sequential ``submit``
    for the same query set, across all three engines."""
    g, dqueries, refs = setup
    k = 1 if engine_name == "mapreduce" else 4   # 1 partition per device
    seq = make_session(g, engine_name, k=k)
    seq_res = [seq.submit(dq) for dq in dqueries]
    sh = make_session(g, engine_name, k=k)
    report = sh.submit_many(dqueries)
    # OPAT and TraditionalMP both share (OPAT: one partition advancing the
    # batch; TMP: one stacked top-p bundle carrying every waiter's plans);
    # MapReduceMP has no host loop to share and drains sequentially
    assert report.shared == (engine_name in ("opat", "traditional"))
    assert [r.name for r in report.results] == [dq.name for dq in dqueries]
    for sres, bres, dq in zip(seq_res, report.results, dqueries):
        assert np.array_equal(sres.answers, bres.answers), dq.name
        assert np.array_equal(bres.answers, refs[dq.name]), dq.name
        assert len(bres.reports) == len(dq.disjuncts)
        assert bres.latency_s >= 0.0


def test_shared_batch_budgets_respected(setup):
    """Per-query budgets retire queries independently inside one shared
    batch: every returned row is a true answer and each query returns
    min(K, total) unique rows."""
    g, dqueries, refs = setup
    sess = make_session(g)
    batch = dqueries * 3                        # 9 overlapping queries
    report = sess.submit_many(batch, max_answers=2)
    assert len(report.results) == len(batch)
    for res, dq in zip(report.results, batch):
        ref = refs[dq.name]
        refset = {tuple(r) for r in ref}
        assert all(tuple(r) in refset for r in res.answers), dq.name
        assert res.n_answers == min(2, ref.shape[0]), dq.name
        for rep in res.reports:
            assert rep.stats.answers_requested == 2


def test_submit_many_per_query_budget_list(setup):
    g, dqueries, refs = setup
    sess = make_session(g)
    budgets = [1, None, 3]
    report = sess.submit_many(dqueries, max_answers=budgets)
    for res, dq, b in zip(report.results, dqueries, budgets):
        ref = refs[dq.name]
        want = ref.shape[0] if b is None else min(b, ref.shape[0])
        assert res.n_answers == want, dq.name
    with pytest.raises(ValueError):
        sess.submit_many(dqueries, max_answers=[1])   # wrong length


def test_budget_zero_does_no_loads(setup):
    g, dqueries, _ = setup
    sess = make_session(g)
    report = sess.submit_many(dqueries, max_answers=0)
    assert report.loads == []
    for res in report.results:
        assert res.n_answers == 0 and res.n_loads == 0


# ---------------------------------------------------------------------------
# shared-load amortization (acceptance)
# ---------------------------------------------------------------------------

def test_shared_fewer_cold_loads_than_isolated(setup):
    """Acceptance: a batch of >= 8 overlapping queries pays strictly fewer
    cold partition loads shared than served in isolation (store cleared
    between queries, the no-sharing baseline), at identical answers."""
    g, dqueries, refs = setup
    batch = dqueries * 3                        # 9 overlapping queries
    iso = make_session(g)
    iso0 = iso.load_stats.copy()
    iso_answers = []
    for dq in batch:
        iso.store.clear()
        iso_answers.append(iso.submit(dq).answers)
    iso_cold = (iso.load_stats - iso0).cold_loads

    sh = make_session(g)
    report = sh.submit_many(batch)
    assert report.load_stats.cold_loads < iso_cold
    # shared workload loads are amortized: fewer load events than the sum
    # of per-query sequences
    assert report.n_loads < sum(r.n_loads for r in report.results)
    for res, ref_a in zip(report.results, iso_answers):
        assert np.array_equal(res.answers, ref_a), res.name
    # one batched evaluation really advanced many queries at once
    assert max(report.batch_sizes) >= 8


def test_round_scoped_load_stats(setup):
    """Satellite: LoadStats deltas are scoped to the scheduler round —
    the report's delta is the store's exact delta over the round, and each
    query's counters cover exactly the loads it participated in."""
    g, dqueries, _ = setup
    sess = make_session(g)
    stats0 = sess.load_stats.copy()
    report = sess.submit_many(dqueries)
    delta = sess.load_stats - stats0
    assert report.load_stats == delta
    # round totals: one store get per workload load event
    assert delta.hits + delta.misses == report.n_loads
    for res in report.results:
        # single-disjunct queries: one get per participated round
        part = res.load_stats
        assert part.hits + part.misses == res.n_loads
        assert part.cold_loads <= report.load_stats.cold_loads
    # a query participating in every round sees the round's cold loads;
    # the ROUND still counts each shared cold load once, so summing the
    # per-query views over-counts exactly the sharing factor
    assert sum(r.load_stats.cold_loads for r in report.results) \
        >= report.load_stats.cold_loads
    # interleaved single submits stay correctly scoped after a batch
    res = sess.submit(dqueries[0])
    assert res.load_stats.hits + res.load_stats.misses == res.n_loads


def test_retirement_releases_partitions(setup):
    """Satellite: budget retirement drops queries from the partition index
    and (with release_retired) releases store entries nobody pending can
    use — observable via LoadStats.released and the store contents."""
    g, dqueries, _ = setup
    sess = make_session(g, cache_parts=2)
    sched = sess.scheduler(release_retired=True)
    for dq in dqueries:
        sched.admit(dq, max_answers=1)
    assert sched.n_pending == sum(len(dq.disjuncts) for dq in dqueries)
    assert sched.partition_waiters()            # index non-empty up front
    report = sched.run()
    assert sched.n_pending == 0
    assert sched.partition_waiters() == {}      # retired queries dropped out
    stats = report.load_stats
    assert stats.released > 0                   # retirement really released
    # released entries are gone from the device cache
    assert all(not sess.store.contains(p) for p in set(report.loads))
    # and the capacity-bounded LRU evicted at session scope as usual
    assert stats.released + stats.evictions > 0


def test_streaming_admission_two_rounds(setup):
    """The scheduler is a stream: admit -> run -> admit -> run reports
    each query exactly once, and the second round reuses residency."""
    g, dqueries, refs = setup
    sess = make_session(g)
    sched = sess.scheduler()
    empty = sched.run()
    assert empty.results == [] and empty.loads == []
    sched.admit(dqueries[0])
    r1 = sched.run()
    assert [r.name for r in r1.results] == [dqueries[0].name]
    sched.admit(dqueries[1])
    r2 = sched.run()
    assert [r.name for r in r2.results] == [dqueries[1].name]
    assert np.array_equal(r1.results[0].answers, refs[dqueries[0].name])
    assert np.array_equal(r2.results[0].answers, refs[dqueries[1].name])
    # round 2 found round 1's partitions device-resident
    assert r2.load_stats.warm_loads > 0


def test_scheduler_refuses_rebound_session(setup):
    """GraphSession.repartition() rebinds store/layout; a scheduler built
    against the old binding must refuse loudly instead of mixing pids."""
    g, dqueries, _ = setup
    sess = make_session(g)
    sched = sess.scheduler()
    sched.admit(dqueries[0])
    sched.run()
    sess.repartition()
    with pytest.raises(RuntimeError, match="rebound"):
        sched.admit(dqueries[1])
    with pytest.raises(RuntimeError, match="rebound"):
        sched.run()
    # a fresh scheduler against the new binding works
    assert sess.submit_many([dqueries[1]]).results[0].n_answers >= 0


def test_submit_many_feeds_workload_profile_like_submit(setup):
    """Satellite: the profile absorbs batched results exactly as single
    submits do — same queries/answers served, same answer-span
    observations (the spans depend only on the answers)."""
    g, dqueries, _ = setup
    seq = make_session(g)
    for dq in dqueries:
        seq.submit(dq)
    sh = make_session(g)
    sh.submit_many(dqueries)
    p_seq, p_sh = seq.workload_profile(), sh.workload_profile()
    assert p_sh["queries_served"] == p_seq["queries_served"]
    assert p_sh["answers_served"] == p_seq["answers_served"]
    assert p_sh["answer_spans"] == p_seq["answer_spans"]
    assert p_sh["assignment"] == p_seq["assignment"]
    # per-partition load counters exist for the shared path too (they
    # count each query's participations, so totals can only be smaller)
    assert sum(p["loads"] for p in p_sh["partitions"]) > 0


# ---------------------------------------------------------------------------
# workload JSONL round trip (serve --workload format)
# ---------------------------------------------------------------------------

def test_query_jsonl_roundtrip(setup, tmp_path):
    g, dqueries, refs = setup
    path = tmp_path / "w.jsonl"
    with open(path, "w") as f:
        for dq in dqueries:
            f.write(json.dumps(dq.to_json_dict()) + "\n")
    with open(path) as f:
        loaded = [DisjunctiveQuery.from_json_dict(json.loads(l)) for l in f]
    assert [dq.name for dq in loaded] == [dq.name for dq in dqueries]
    sess = make_session(g)
    report = sess.submit_many(loaded)
    for res, dq in zip(report.results, dqueries):
        assert np.array_equal(res.answers, refs[dq.name]), dq.name
    # a bare conjunctive line is accepted as a single-disjunct query
    bare = DisjunctiveQuery.from_json_dict(
        dqueries[0].disjuncts[0].to_json_dict())
    assert len(bare.disjuncts) == 1 and bare.name == dqueries[0].name
    # a malformed line fails at parse time, not deep inside serving
    with pytest.raises(ValueError, match="no disjuncts"):
        DisjunctiveQuery.from_json_dict({"name": "bad", "disjuncts": []})


# ---------------------------------------------------------------------------
# throughput sweep (the benchmark the CI full lane smokes)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_shared_sweep_acceptance():
    """Acceptance: on a batch of 8 overlapping skewed-workload queries the
    shared scheduler performs strictly fewer cold loads than isolated
    serving, with identical oracle-verified answers, and the table inputs
    (loads/query, q/s) are populated for both modes."""
    from benchmarks.common import run_shared_sweep
    res = run_shared_sweep(batch_sizes=(8,))
    assert res.answers_identical and res.oracle_match
    iso = res.phase(8, "isolated")
    sh = res.phase(8, "shared")
    assert sh.cold_loads < iso.cold_loads
    assert sh.loads_per_query < iso.loads_per_query
    assert iso.qps > 0 and sh.qps > 0
    assert iso.n_answers == sh.n_answers > 0


# ---------------------------------------------------------------------------
# scheduler fairness under skew (ISSUE-5 satellite)
# ---------------------------------------------------------------------------

def test_fairness_aging_bounds_starvation_rounds():
    """A no-overlap query's partition (one waiter, SNI 1) can be passed
    over forever by pure yield ranking while hot traffic keeps a big
    shared score alive; the aging term (rounds-waiting x SNI, weighted by
    fairness_gamma) guarantees it reaches rank 0 within a bounded number
    of rounds."""
    rng = np.random.default_rng(0)

    def waiting(age):
        # partition 0: three persistent hot waiters (base score 75);
        # partition 9: the lone cold waiter, aged `age` rounds
        return {0: [(50, 0.5, 0), (50, 0.5, 0), (50, 0.5, 0)],
                9: [(1, 0.5, age)]}

    # gamma = 0 (the default): starves at every age — pure yield
    for age in (0, 10, 100, 10_000):
        assert rank_partitions_shared(
            MAX_YIELD_SHARED, waiting(age), rng)[0] == 0
    # gamma > 0: served within ceil(hot_score / (gamma * sni)) rounds
    gamma = 1.0
    first = next(age for age in range(200) if rank_partitions_shared(
        MAX_YIELD_SHARED, waiting(age), rng, fairness_gamma=gamma)[0] == 9)
    assert first <= 75       # 0.5 + gamma*age > 75  <=>  age >= 75
    # the same bound applies to the max-sn shared ranking (base 150)
    first_sn = next(age for age in range(400) if rank_partitions_shared(
        MAX_SN, waiting(age), rng, fairness_gamma=gamma)[0] == 9)
    assert first_sn <= 150
    # two-tuple observations (no age recorded) still rank — age reads 0
    assert rank_partitions_shared(MAX_YIELD_SHARED,
                                  {0: [(10, 0.5)], 1: [(1, 0.5)]},
                                  rng, fairness_gamma=5.0)[0] == 0


def test_fairness_gamma_threaded_and_semantics_preserved(setup):
    """fairness_gamma reaches the shared ranking through submit_many /
    scheduler() and never changes answer sets — only the load ORDER may
    differ."""
    g, dqueries, refs = setup
    for gamma in (0.0, 2.5):
        sess = make_session(g)
        report = sess.submit_many(dqueries, fairness_gamma=gamma)
        for r in report.results:
            assert np.array_equal(r.answers, refs[r.name]), (gamma, r.name)
    sess = make_session(g)
    sched = sess.scheduler(fairness_gamma=1.5)
    assert sched.fairness_gamma == 1.5
    with pytest.raises(ValueError, match="fairness_gamma"):
        sess.scheduler(fairness_gamma=-0.1)
