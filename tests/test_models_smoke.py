"""Per-architecture smoke tests (assignment requirement): instantiate a
REDUCED config of the same family and run one forward/train step on CPU,
asserting output shapes + no NaNs; plus prefill/decode consistency.

XLA-compile-heavy (whole-model jit per arch), so the module is marked
``slow``: it dominates suite wall time and belongs to the CI full lane."""
import numpy as np
import pytest

pytestmark = pytest.mark.slow

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, reduced, shape_applicable
from repro.configs.registry import ShapeSpec, concrete_batch
from repro.models.config import FAMILY_AUDIO
from repro.models.transformer import abstract_params, forward, init_params
from repro.serving import decode_step, prefill
from repro.train import TrainConfig, init_opt_state, make_train_step

TINY = ShapeSpec("tiny", "train", 32, 2)
ALL_ARCHS = sorted(ARCHS)


def _grow_kv(caches):
    def g(path, x):
        leaf = str(path[-1].key if hasattr(path[-1], "key") else path[-1])
        if leaf in ("k", "v") and x.ndim >= 4:
            pad = [(0, 0)] * x.ndim
            pad[x.ndim - 3] = (0, 1)
            return jnp.pad(x, pad)
        return x
    return jax.tree_util.tree_map_with_path(g, caches)


@pytest.fixture(scope="module")
def states():
    out = {}
    for aid in ALL_ARCHS:
        cfg = reduced(ARCHS[aid])
        params = init_params(cfg, jax.random.PRNGKey(0))
        out[aid] = (cfg, params)
    return out


@pytest.mark.parametrize("aid", ALL_ARCHS)
def test_forward_shapes_and_finite(states, aid):
    cfg, params = states[aid]
    batch = concrete_batch(cfg, TINY, seed=1)
    logits, aux = forward(params, cfg, batch, remat=False)
    assert logits.shape == (TINY.batch, TINY.seq, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("aid", ALL_ARCHS)
def test_train_step_finite_and_updates(states, aid):
    cfg, params = states[aid]
    batch = concrete_batch(cfg, TINY, seed=1)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, TrainConfig(remat=True)))
    p2, o2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"])) and float(m["grad_norm"]) > 0
    # at least one parameter actually moved
    moved = jax.tree.reduce(
        lambda a, b: a or b,
        jax.tree.map(lambda a, b: bool(jnp.any(a != b)), params, p2))
    assert moved
    assert int(o2["step"]) == 1


@pytest.mark.parametrize("aid", ALL_ARCHS)
def test_prefill_matches_forward(states, aid):
    cfg, params = states[aid]
    batch = concrete_batch(cfg, TINY, seed=1)
    batch.pop("labels", None)
    logits_full, _ = forward(params, cfg, batch, remat=False)
    last, _ = prefill(params, cfg, batch)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(logits_full[:, -1]),
                               rtol=2e-4, atol=2e-3)


@pytest.mark.parametrize("aid", ALL_ARCHS)
def test_decode_matches_forward(states, aid):
    cfg, params = states[aid]
    S = TINY.seq
    batch = concrete_batch(cfg, TINY, seed=1)
    batch.pop("labels", None)
    _, caches = prefill(params, cfg, batch)
    rng = np.random.default_rng(3)
    if cfg.family == FAMILY_AUDIO:
        fe = jnp.asarray(rng.normal(size=(TINY.batch, cfg.frontend_dim()))
                         .astype(np.float32))
        ext = {"frame_embeds": jnp.concatenate(
            [batch["frame_embeds"], fe[:, None]], axis=1)}
        inp = {"frame_embeds": fe}
    else:
        tok = jnp.asarray(rng.integers(0, cfg.vocab, TINY.batch), jnp.int32)
        ext = dict(batch)
        ext["tokens"] = jnp.concatenate([batch["tokens"], tok[:, None]], axis=1)
        inp = {"token": tok}
    logits_ext, _ = forward(params, cfg, ext, remat=False)
    dl, new_caches = decode_step(params, cfg, _grow_kv(caches), inp,
                                 jnp.int32(S))
    np.testing.assert_allclose(np.asarray(dl), np.asarray(logits_ext[:, -1]),
                               rtol=2e-4, atol=2e-3)
    # caches keep their shapes
    same = jax.tree.map(lambda a, b: a.shape == b.shape,
                        _grow_kv(caches), new_caches)
    assert jax.tree.reduce(lambda x, y: x and y, same)


@pytest.mark.parametrize("aid", ALL_ARCHS)
def test_abstract_params_match_init(states, aid):
    cfg, params = states[aid]
    abs_p = abstract_params(cfg)
    shapes_match = jax.tree.map(
        lambda a, b: a.shape == b.shape and a.dtype == b.dtype, abs_p, params)
    assert jax.tree.reduce(lambda x, y: x and y, shapes_match)


def test_full_configs_param_counts():
    """Config-level n_params() should land near each arch's advertised
    size (the counting includes frontends/embeddings, so tolerances are
    generous but catch transposed/missing dims)."""
    expect = {
        "qwen1_5_110b": 111e9,
        "qwen2_1_5b": 1.5e9,
        "qwen3_4b": 4e9,
        "granite_3_2b": 2.5e9,
        "deepseek_moe_16b": 16e9,
        "granite_moe_1b_a400m": 1.3e9,
        "musicgen_medium": 1.5e9,
        "llava_next_mistral_7b": 7.2e9,
        "xlstm_125m": 125e6,
        "recurrentgemma_9b": 8.5e9,
    }
    for aid, target in expect.items():
        n = ARCHS[aid].n_params()
        assert 0.5 * target < n < 1.8 * target, (aid, n, target)


def test_moe_active_params_less_than_total():
    cfg = ARCHS["deepseek_moe_16b"]
    assert cfg.n_active_params() < 0.35 * cfg.n_params()


def test_long_500k_applicability():
    long = SHAPES["long_500k"]
    ok = {aid: shape_applicable(ARCHS[aid], long)[0] for aid in ALL_ARCHS}
    assert ok["xlstm_125m"] and ok["recurrentgemma_9b"]
    assert sum(ok.values()) == 2   # exactly the two sub-quadratic archs
