"""SLO-aware serving front end (serving/cost.py, serving/frontend.py).

Covers the ISSUE-7 satellite/acceptance list:
  * cost-model monotonicity in SNI and CC, and calibration convergence
    under a constant synthetic latency;
  * admission-time prediction is catalog/manifest-only (in-RAM and
    out-of-core sessions price identically, no shard ever read);
  * no-SLO front end is byte-identical to plain ``submit_many`` (answers
    AND the partition-load schedule);
  * seeded overload: the strict class is fully served, only lower classes
    degrade/shed, the shed set is deterministic across runs, counters are
    exact, and every shed outcome carries a ``shed_reason``;
  * non-shed answers oracle-identical under the effective budget;
  * TraditionalMP shared batching: stacked top-p answers bit-identical to
    sequential submit, with real multi-query sharing observed;
  * deadline-ordering: urgency outranks hotter slack-rich work in the
    shared partition ranking.
"""
import math

import numpy as np
import pytest

from repro.core import (EngineConfig, GraphSession, MAX_YIELD_SHARED,
                        match_disjunctive, rank_partitions_shared)
from repro.core.plan import generate_plan
from repro.data.generators import subgen_like_graph, subgen_queries
from repro.serving import (CostModel, Request, SLOClass, default_slo_classes,
                           parse_slo_spec, required_partition_mask,
                           requests_from_workload, work_units)
from repro.serving.frontend import SHED_DEADLINE


@pytest.fixture(scope="module")
def setup():
    g = subgen_like_graph(n_nodes=250, n_edges=700, n_embed=10, seed=3)
    dqueries = subgen_queries(g)
    refs = {dq.name: match_disjunctive(g, dq, q_pad=8) for dq in dqueries}
    return g, dqueries, refs


def make_session(g, engine="opat", k=4, **kw):
    return GraphSession(g, k=k, scheme="kway_shem", engine=engine, seed=1,
                        processors=2, config=EngineConfig(cap=32768), **kw)


# ---------------------------------------------------------------------------
# cost model: monotonicity + calibration (satellite)
# ---------------------------------------------------------------------------

def test_work_units_monotone_in_sni_and_cc():
    sni = np.array([10, 20, 0, 5])
    cc = np.array([1, 2, 1, 3])
    req = np.array([True, True, False, True])
    base = work_units(sni, cc, req)
    # more seeded SNI mass in a required partition -> more work
    assert work_units(sni + 5, cc, req) > base
    # a more fragmented required partition -> more work
    cc2 = cc.copy(); cc2[1] += 4
    assert work_units(sni, cc2, req) > base
    # growing the required set -> more work
    req2 = np.array([True, True, True, True])
    assert work_units(sni, cc, req2) >= base
    # a longer plan multiplies everything
    assert work_units(sni, cc, req, n_steps=3) > base
    # CC of an UNREQUIRED partition is irrelevant
    cc3 = cc.copy(); cc3[2] += 100
    assert work_units(sni, cc3, req) == base


def test_cost_model_predicts_from_catalog_only(setup, tmp_path):
    """In-RAM and out-of-core-reopened sessions price identically: the
    model reads only start_label_counts + manifest components, never a
    shard (the OOC session performs zero disk reads while predicting)."""
    g, dqueries, _ = setup
    ram = make_session(g)
    ram.save(str(tmp_path / "gdir"))
    ooc = GraphSession.open(str(tmp_path / "gdir"),
                            config=EngineConfig(cap=32768), seed=1)
    cm_ram, cm_ooc = CostModel(ram.pg), CostModel(ooc.pg)
    reads0 = ooc.load_stats.disk_reads
    for dq in dqueries:
        plans_r = [generate_plan(q, ram.graph, ram.catalog)
                   for q in dq.disjuncts]
        plans_o = [generate_plan(q, ooc.graph, ooc.catalog)
                   for q in dq.disjuncts]
        er = cm_ram.predict_plans(plans_r, 16)
        eo = cm_ooc.predict_plans(plans_o, 16)
        assert er.work_units == pytest.approx(eo.work_units)
        assert er.loads == eo.loads > 0
        for p_r in plans_r:
            assert required_partition_mask(ram.pg, p_r).shape == (ram.k,)
    assert ooc.load_stats.disk_reads == reads0     # no shard was touched


def test_cost_model_budget_factor_monotone(setup):
    g, dqueries, _ = setup
    sess = make_session(g)
    cm = CostModel(sess.pg)
    plans = [generate_plan(q, sess.graph, sess.catalog)
             for q in dqueries[0].disjuncts]
    exhaustive = cm.predict_plans(plans, None).work_units
    assert cm.predict_plans(plans, 0).work_units == 0.0   # K=0: no work
    small = cm.predict_plans(plans, 1).work_units
    big = cm.predict_plans(plans, 10_000).work_units
    assert 0.0 < small <= big <= exhaustive
    assert big == pytest.approx(exhaustive)   # huge K = no budget discount


def test_cost_model_calibration_converges(setup):
    """EWMA calibration: after ~50 observations of a constant latency the
    prediction lands within 5% (and the model reports calibrated)."""
    g, dqueries, _ = setup
    sess = make_session(g)
    cm = CostModel(sess.pg, default_rate_s=123.0)   # far-off initial rate
    plans = [generate_plan(q, sess.graph, sess.catalog)
             for q in dqueries[0].disjuncts]
    assert not cm.calibrated
    true_latency = 0.25
    for _ in range(50):
        est = cm.predict_plans(plans, None)
        cm.observe(est, true_latency)
    assert cm.calibrated and cm.observations == 50
    final = cm.predict_plans(plans, None)
    assert final.latency_s == pytest.approx(true_latency, rel=0.05)
    # a nearby bucket borrows the calibrated rate rather than the default
    other = cm.predict_plans(plans, 1)
    assert other.calibrated
    snap = cm.snapshot()
    assert snap["observations"] == 50 and snap["rates_s_per_unit"]


def test_parse_slo_spec():
    classes = parse_slo_spec("interactive=0.5,batch=5,exhaustive=inf")
    assert [c.name for c in classes] == ["interactive", "batch", "exhaustive"]
    assert classes[0].deadline_s == 0.5 and classes[0].priority == 0
    assert not classes[0].sheddable          # strict default kept
    assert classes[1].sheddable and classes[1].degradable
    assert math.isinf(classes[2].deadline_s) and classes[2].deferrable
    # unknown names become degradable+sheddable, priority by position
    custom = parse_slo_spec("gold=1,silver=10")
    assert custom[0].name == "gold" and custom[0].sheddable
    assert custom[1].priority == 1
    with pytest.raises(ValueError):
        parse_slo_spec("noequals")
    with pytest.raises(ValueError):
        parse_slo_spec("bad=-1")
    with pytest.raises(ValueError):
        parse_slo_spec("")


# ---------------------------------------------------------------------------
# byte-identity without SLOs (acceptance)
# ---------------------------------------------------------------------------

def test_no_slo_frontend_byte_identical_to_submit_many(setup):
    """Acceptance: with no SLO configured, answers AND the scheduling
    (workload load sequence, batch sizes) are byte-identical to plain
    ``submit_many``."""
    g, dqueries, _ = setup
    plain = make_session(g)
    ref = plain.submit_many(dqueries, max_answers=8)
    fe_sess = make_session(g)
    fe = fe_sess.frontend(slo_classes=[])
    rep = fe.serve([Request(dq, max_answers=8) for dq in dqueries])
    assert rep.schedule is not None
    assert rep.schedule.loads == ref.loads
    assert rep.schedule.batch_sizes == ref.batch_sizes
    assert [o.name for o in rep.outcomes] == [r.name for r in ref.results]
    for o, r in zip(rep.outcomes, ref.results):
        assert np.array_equal(o.result.answers, r.answers)
    assert rep.counters["shed"] == 0 if "shed" in rep.counters else True
    # the profile carries no "serving" block -> byte-identical profiles
    assert "serving" not in fe_sess.workload_profile()
    assert fe_sess.workload_profile() == plain.workload_profile()


def test_all_none_slo_requests_take_plain_path(setup):
    g, dqueries, _ = setup
    sess = make_session(g)
    fe = sess.frontend()          # classes configured, but no request uses one
    rep = fe.serve([Request(dq) for dq in dqueries])
    assert rep.schedule is not None and rep.per_class == {}


# ---------------------------------------------------------------------------
# seeded overload: deadlines, degradation, shedding (acceptance)
# ---------------------------------------------------------------------------

def overload_frontend(sess, **kw):
    """A deterministically overloaded front end: the uncalibrated default
    rate prices every query at ~10s, far beyond the batch deadline."""
    cm = CostModel(sess.pg, default_rate_s=2.0)
    classes = [
        SLOClass("interactive", deadline_s=60.0, priority=0),
        SLOClass("batch", deadline_s=0.004, priority=1,
                 degradable=True, sheddable=True),
    ]
    return sess.frontend(cost_model=cm, slo_classes=classes, **kw)


def overload_requests(dqueries):
    return [Request(dq, slo_class=("interactive" if i % 2 == 0 else "batch"),
                    max_answers=16)
            for i, dq in enumerate(dqueries * 3)]


def test_overload_sheds_only_lower_classes_deterministically(setup):
    """Acceptance: under seeded overload the strict class is fully served
    (meeting its deadline), only sheddable classes shed — each with an
    explicit shed_reason — the counters are exact, and two identical runs
    produce the identical shed set."""
    g, dqueries, refs = setup

    def run():
        sess = make_session(g)
        fe = overload_frontend(sess)
        rep = fe.serve(overload_requests(dqueries))
        return sess, rep

    sess, rep = run()
    interactive = [o for o in rep.outcomes if o.slo_class == "interactive"]
    assert interactive and all(o.status == "ok" for o in interactive)
    assert all(o.deadline_met for o in interactive)
    shed = rep.shed
    assert shed, "the overload must shed something"
    assert all(o.slo_class == "batch" for o in shed)
    assert all(o.shed_reason == SHED_DEADLINE for o in shed)
    # exact counters
    n = len(overload_requests(dqueries))
    assert rep.counters["arrived"] == n
    assert rep.counters["shed"] == len(shed)
    assert rep.counters["served"] == n - len(shed)
    assert rep.counters["admitted"] == n - len(shed)
    assert rep.shed_by_reason == {SHED_DEADLINE: len(shed)}
    # non-shed answers oracle-identical under the effective budget
    for o in rep.served:
        ref = refs[o.name]
        refset = {tuple(r) for r in ref}
        assert all(tuple(r) in refset for r in o.result.answers), o.name
        budget = o.max_answers
        assert o.result.answers.shape[0] >= min(budget, ref.shape[0])
    # deterministic: an identical second run sheds the identical set
    _, rep2 = run()
    assert [(o.name, o.slo_class, o.shed_reason) for o in rep2.shed] == \
        [(o.name, o.slo_class, o.shed_reason) for o in shed]
    assert rep2.counters == rep.counters
    # the session profile gained the serving block with the same counters
    prof = sess.workload_profile()
    assert prof["serving"]["counters"]["shed"] == rep.counters["shed"]
    assert prof["serving"]["shed_by_reason"] == rep.shed_by_reason
    assert "interactive" in prof["serving"]["classes"]


def test_degradation_shrinks_budget_before_shedding(setup):
    """A batch query whose FULL-budget prediction misses the deadline but
    whose degraded (K=degraded_max_answers) prediction fits is served
    degraded — correct answers under the shrunken budget, exact
    counters."""
    g, dqueries, refs = setup
    sess = make_session(g)
    cm = CostModel(sess.pg, default_rate_s=2.0)
    # deadline sized so the degraded estimate fits but the full one misses:
    # budget factor floors at min_budget_frac=0.05 -> 20x shrink available
    plans = [generate_plan(q, sess.graph, sess.catalog)
             for q in dqueries[0].disjuncts]
    full = cm.predict_plans(plans, 10_000).latency_s
    degraded = cm.predict_plans(plans, 4).latency_s
    assert degraded < full
    deadline = (degraded + full) / 2
    classes = [SLOClass("batch", deadline_s=deadline, priority=0,
                        degradable=True, sheddable=True,
                        degraded_max_answers=4)]
    fe = sess.frontend(cost_model=cm, slo_classes=classes)
    rep = fe.serve([Request(dqueries[0], slo_class="batch",
                            max_answers=10_000)])
    assert rep.counters == {"arrived": 1, "admitted": 1, "served": 1,
                            "degraded": 1, "deferred": 0, "shed": 0}
    o = rep.outcomes[0]
    assert o.status == "ok" and o.degraded and o.max_answers == 4
    ref = refs[dqueries[0].name]
    refset = {tuple(r) for r in ref}
    assert all(tuple(r) in refset for r in o.result.answers)
    assert o.result.answers.shape[0] >= min(4, ref.shape[0])


def test_exhaustive_defers_until_drain(setup):
    """Deferrable (exhaustive) work parks while deadline work is in flight
    and is served at drain — still exhaustively correct."""
    g, dqueries, refs = setup
    sess = make_session(g)
    fe = sess.frontend()          # default classes: exhaustive is deferrable
    reqs = [Request(dqueries[0], slo_class="exhaustive"),
            Request(dqueries[1], slo_class="interactive", max_answers=8),
            Request(dqueries[2], slo_class="interactive", max_answers=8)]
    rep = fe.serve(reqs)
    assert rep.counters["deferred"] == 1
    ex = next(o for o in rep.outcomes if o.slo_class == "exhaustive")
    assert ex.status == "ok" and ex.deferred
    assert np.array_equal(ex.result.answers, refs[dqueries[0].name])
    # the deferred query finished no earlier than every interactive one
    for o in rep.outcomes:
        if o.slo_class == "interactive":
            assert o.finished_round <= ex.finished_round


def test_shed_policy_deadline_and_never(setup):
    g, dqueries, _ = setup
    n = len(overload_requests(dqueries))
    sess = make_session(g)
    rep = overload_frontend(sess, shed_policy="deadline").serve(
        overload_requests(dqueries))
    assert rep.counters["shed"] > 0 and rep.counters["degraded"] == 0
    assert all(o.shed_reason == "deadline-policy" for o in rep.shed)
    sess2 = make_session(g)
    rep2 = overload_frontend(sess2, shed_policy="never").serve(
        overload_requests(dqueries))
    assert rep2.counters == {"arrived": n, "admitted": n, "served": n,
                             "degraded": 0, "deferred": 0, "shed": 0}
    with pytest.raises(ValueError, match="shed_policy"):
        sess2.frontend(shed_policy="bogus")
    with pytest.raises(ValueError, match="unknown slo_class"):
        overload_frontend(make_session(g)).serve(
            [Request(dqueries[0], slo_class="platinum")])


# ---------------------------------------------------------------------------
# deadline ordering in the shared ranking
# ---------------------------------------------------------------------------

def test_urgency_outranks_hotter_slack_rich_work():
    """The urgency term (obs[3]): a deadline-critical query's partition
    outranks a hotter one, and all-zero urgency is bit-identical to the
    plain ranking."""
    rng = np.random.default_rng(0)
    # pid 0 is hotter (summed yield 15); pid 1's lone waiter is urgent
    waiting = {0: [(10, 0.5, 0, 0.0), (20, 0.5, 0, 0.0)],
               1: [(6, 0.5, 0, 0.0)]}
    assert rank_partitions_shared(MAX_YIELD_SHARED, waiting, rng)[0] == 0
    waiting[1] = [(6, 0.5, 0, 1000.0)]
    assert rank_partitions_shared(MAX_YIELD_SHARED, waiting, rng)[0] == 1
    # all-zero urgency: same scores, same order as the 2/3-tuple forms
    rng_a, rng_b = np.random.default_rng(7), np.random.default_rng(7)
    flat = {0: [(10, 0.5, 0, 0.0)], 1: [(10, 0.5, 0, 0.0)]}
    bare = {0: [(10, 0.5)], 1: [(10, 0.5)]}
    assert rank_partitions_shared(MAX_YIELD_SHARED, flat, rng_a) == \
        rank_partitions_shared(MAX_YIELD_SHARED, bare, rng_b)


def test_scheduler_set_urgency_threads_to_jobs(setup):
    g, dqueries, _ = setup
    sess = make_session(g)
    sched = sess.scheduler()
    qid = sched.admit(dqueries[0], urgency=2.5)
    assert all(j.urgency == 2.5 for j in sched._admitted[qid].jobs)
    sched.set_urgency(qid, 7.0)
    assert all(j.urgency == 7.0 for j in sched._admitted[qid].jobs)
    sched.set_urgency(999, 1.0)          # unknown qid: ignored
    report = sched.run()
    assert report.results[0].qid == qid  # results carry the admission id


# ---------------------------------------------------------------------------
# TraditionalMP shared batching (tentpole roll-in)
# ---------------------------------------------------------------------------

def test_tmp_shared_stacked_batching_shares_loads(setup):
    """TraditionalMP through the scheduler: one stacked top-p bundle
    carries several queries' plans (batch_sizes > 1 observed), answers
    bit-identical to sequential submit."""
    g, dqueries, refs = setup
    seq = make_session(g, engine="traditional")
    seq_answers = [seq.submit(dq).answers for dq in dqueries]
    sh = make_session(g, engine="traditional")
    report = sh.submit_many(dqueries * 2)      # overlap guarantees sharing
    assert report.shared
    assert max(report.batch_sizes) > 1         # real multi-query sharing
    for res, dq in zip(report.results, dqueries * 2):
        assert np.array_equal(res.answers, refs[dq.name]), dq.name
    for res, ref_a in zip(report.results[:len(dqueries)], seq_answers):
        assert np.array_equal(res.answers, ref_a)


def test_frontend_works_on_traditional_engine(setup):
    g, dqueries, refs = setup
    sess = make_session(g, engine="traditional")
    fe = sess.frontend()
    rep = fe.serve([Request(dq, slo_class="interactive")
                    for dq in dqueries])
    assert all(o.status == "ok" for o in rep.outcomes)
    for o in rep.outcomes:
        assert np.array_equal(o.result.answers, refs[o.name]), o.name


# ---------------------------------------------------------------------------
# workload JSONL: arrivals + SLO classes ride along (satellite)
# ---------------------------------------------------------------------------

def test_requests_from_workload_lines(setup):
    g, dqueries, _ = setup
    lines = []
    for i, dq in enumerate(dqueries):
        d = dq.to_json_dict()
        d["arrival_ms"] = i * 10.0
        if i % 2 == 0:
            d["slo_class"] = "interactive"
        lines.append(d)
    reqs = requests_from_workload(lines, default_slo="batch",
                                  default_max_answers=5)
    assert [r.arrival_s for r in reqs] == [0.0, 0.01, 0.02]
    assert [r.slo_class for r in reqs] == ["interactive", "batch",
                                           "interactive"]
    assert all(r.max_answers == 5 for r in reqs)
    assert [r.query.name for r in reqs] == [dq.name for dq in dqueries]


def test_default_slo_classes_shape():
    classes = default_slo_classes()
    by_name = {c.name: c for c in classes}
    assert not by_name["interactive"].sheddable       # strict
    assert by_name["batch"].degradable and by_name["batch"].sheddable
    assert by_name["exhaustive"].deferrable
    assert [c.priority for c in classes] == [0, 1, 2]
