"""Multilevel partitioner (METIS/KaHIP stand-in) behaviour."""
import numpy as np
import pytest

from repro.core import SCHEMES, partition_graph, partition_quality
from repro.data.generators import imdb_like_graph


@pytest.mark.parametrize("scheme", sorted(SCHEMES))
def test_all_schemes_valid(small_graph, scheme):
    k = 4
    assign = partition_graph(small_graph, k, scheme)
    assert assign.shape == (small_graph.n_nodes,)
    assert assign.min() >= 0 and assign.max() < k
    sizes = np.bincount(assign, minlength=k)
    assert (sizes > 0).all(), "no empty partitions"


@pytest.mark.parametrize("scheme", sorted(SCHEMES))
def test_balance(scheme):
    g = imdb_like_graph(n_movies=150, n_people=200, seed=3)
    k = 4
    assign = partition_graph(g, k, scheme)
    q = partition_quality(g, assign, k)
    # multilevel with FM refinement: sizes within a loose 35% of perfect
    assert q["imbalance"] < 0.35, q


def test_deterministic_by_seed(small_graph):
    a1 = partition_graph(small_graph, 4, "kway_shem", seed=5)
    a2 = partition_graph(small_graph, 4, "kway_shem", seed=5)
    assert np.array_equal(a1, a2)


def test_cut_beats_random(small_graph):
    """The multilevel partitioner should do much better than random
    assignment on cut size (the metric METIS/KaHIP minimize)."""
    rng = np.random.default_rng(0)
    rand = rng.integers(0, 4, size=small_graph.n_nodes).astype(np.int32)
    q_rand = partition_quality(small_graph, rand, 4)
    q_ml = partition_quality(
        small_graph, partition_graph(small_graph, 4, "eco"), 4)
    assert q_ml["cut"] < q_rand["cut"]


def test_k1_trivial(small_graph):
    assign = partition_graph(small_graph, 1, "fast")
    assert (assign == 0).all()


def test_schemes_differ(small_graph):
    """The six schemes are genuinely different configurations."""
    assigns = {s: partition_graph(small_graph, 4, s) for s in SCHEMES}
    distinct = {a.tobytes() for a in assigns.values()}
    assert len(distinct) >= 3
