"""Answer-budget (top-K) evaluation: the paper's "all or specified number
of answers" mode, uniform across all three engines via the QueryRunner
protocol (core/runner.py).

Invariants asserted per engine:
  * exactly min(K, total) unique answer rows come back,
  * every returned row is in the exhaustive run's answer set,
  * OPAT at K=1 does strictly fewer partition loads than the full run on
    a workload whose answers span partitions (the budget's whole point).
"""
import numpy as np
import pytest

from repro.compat import make_part_mesh
from repro.core import (EngineConfig, MAX_SN, MAX_YIELD, OPATEngine, RunRequest,
                        TraditionalMPEngine, build_catalog, build_partitions, generate_plan,
                        match_query, partition_graph)
from repro.core.mapreduce_mp import MapReduceMPEngine
from repro.core.runner import QueryRunner, RunReport, truncate_answers
from repro.data.generators import subgen_like_graph, subgen_queries

BUDGETS = (0, 1, 3, 10, 10**6)


@pytest.fixture(scope="module")
def setup():
    g = subgen_like_graph(n_nodes=250, n_edges=700, n_embed=10, seed=3)
    assign = partition_graph(g, 4, "kway_shem")
    pg = build_partitions(g, assign, 4)
    cat = build_catalog(g)
    queries = [dq.disjuncts[0] for dq in subgen_queries(g)]
    return g, pg, cat, queries


@pytest.fixture(scope="module")
def engines(setup):
    g, pg, cat, queries = setup
    # MapReduceMP needs one partition per device; this container has one
    # CPU device -> a k=1 partitioning of the same graph
    pg1 = build_partitions(g, np.zeros(g.n_nodes, dtype=np.int32), 1)
    return {
        "opat": OPATEngine(pg, EngineConfig(cap=16384)),
        "traditional": TraditionalMPEngine(pg, 2, EngineConfig(cap=16384)),
        "mapreduce": MapReduceMPEngine(pg1, make_part_mesh(1),
                                       EngineConfig(cap=32768)),
    }


def test_engines_satisfy_runner_protocol(engines):
    for eng in engines.values():
        assert isinstance(eng, QueryRunner)


@pytest.mark.parametrize("engine_name", ["opat", "traditional", "mapreduce"])
def test_budget_returns_min_k_total_subset(setup, engines, engine_name):
    g, pg, cat, queries = setup
    eng = engines[engine_name]
    for q in queries:
        plan = generate_plan(q, g, cat)
        ref = match_query(g, q, q_pad=8)
        refset = {tuple(r) for r in ref}
        total = ref.shape[0]
        for k in BUDGETS:
            rep = eng.run_request(RunRequest(plan=plan, heuristic=MAX_SN,
                                             max_answers=k, seed=1))
            assert isinstance(rep, RunReport)
            got = rep.answers
            assert got.shape[0] == min(k, total), (q.name, k)
            # unique rows, each one a real answer of the exhaustive run
            assert len({tuple(r) for r in got}) == got.shape[0]
            assert all(tuple(r) in refset for r in got), (q.name, k)
            assert rep.stats.answers_requested == k
            assert rep.stats.n_answers == got.shape[0]


@pytest.mark.parametrize("engine_name", ["opat", "traditional", "mapreduce"])
def test_no_budget_matches_oracle(setup, engines, engine_name):
    g, pg, cat, queries = setup
    eng = engines[engine_name]
    for q in queries:
        plan = generate_plan(q, g, cat)
        rep = eng.run_request(RunRequest(plan=plan, heuristic=MAX_SN, seed=1))
        assert rep.stats.answers_requested is None
        assert np.array_equal(np.unique(rep.answers, axis=0),
                              match_query(g, q, q_pad=8)), q.name


def test_opat_k1_fewer_loads_than_full(setup, engines):
    """On a spanning-answer workload, stopping at the first answer must
    load strictly fewer partitions than exhausting the query."""
    g, pg, cat, queries = setup
    eng = engines["opat"]
    checked = 0
    for q in queries:
        plan = generate_plan(q, g, cat)
        if match_query(g, q, q_pad=8).shape[0] == 0:
            continue                      # no answers -> no early exit
        full = eng.run_request(RunRequest(plan=plan, heuristic=MAX_SN, seed=1))
        k1 = eng.run_request(RunRequest(plan=plan, heuristic=MAX_SN,
                                        max_answers=1, seed=1))
        assert k1.stats.n_loads < full.stats.n_loads, q.name
        checked += 1
    assert checked, "workload produced no answerable queries"


def test_max_yield_heuristic_correct_and_budgeted(setup, engines):
    """MAX-YIELD must stay exact without a budget and respect K with one,
    on both host-orchestrated engines."""
    g, pg, cat, queries = setup
    for name in ("opat", "traditional"):
        eng = engines[name]
        for q in queries:
            plan = generate_plan(q, g, cat)
            ref = match_query(g, q, q_pad=8)
            rep = eng.run_request(RunRequest(plan=plan, heuristic=MAX_YIELD,
                                             seed=1))
            assert np.array_equal(np.unique(rep.answers, axis=0), ref), \
                (name, q.name)
            k = 2
            repk = eng.run_request(RunRequest(plan=plan, heuristic=MAX_YIELD,
                                              max_answers=k, seed=1))
            assert repk.answers.shape[0] == min(k, ref.shape[0])


def test_mapreduce_budget_stops_compiled_loop_early(setup):
    """The on-device psum stop condition must cut iterations, not just
    truncate on the host: K=1 on an answer-rich query ends the compiled
    while_loop in fewer iterations than exhaustion.  A tiny expand_block
    staggers completions across iterations so the early exit is visible
    even on one device."""
    g, pg, cat, queries = setup
    pg1 = build_partitions(g, np.zeros(g.n_nodes, dtype=np.int32), 1)
    eng = MapReduceMPEngine(pg1, make_part_mesh(1),
                            EngineConfig(cap=32768, expand_block=8))
    cut = 0
    for q in queries:
        plan = generate_plan(q, g, cat)
        if match_query(g, q, q_pad=8).shape[0] == 0:
            continue
        full = eng.run(plan, seed=1)
        k1 = eng.run(plan, seed=1, max_answers=1)
        assert k1.n_iterations <= full.n_iterations
        cut += int(k1.n_iterations < full.n_iterations)
    # at least one query must genuinely exit early on-device
    assert cut >= 1


def test_run_request_validates_max_answers(setup, engines):
    with pytest.raises(ValueError):
        RunRequest(plan=None, max_answers=-1)


def test_truncate_answers_helper():
    a = np.arange(12, dtype=np.int32).reshape(4, 3)
    assert truncate_answers(a, None).shape[0] == 4
    assert truncate_answers(a, 2).shape[0] == 2
    assert truncate_answers(a, 99).shape[0] == 4


def test_budget_sweep_and_k_table_smoke(tmp_path):
    """The response-time-vs-K benchmark path (run_budget_sweep +
    table_k_budget) — not exercised by the CI benchmark smoke, which runs
    --skip-sweep, so cover it here at tiny scale."""
    import sys
    sys.path.insert(0, ".")
    from benchmarks.common import Workload, run_budget_sweep
    from benchmarks.paper_tables import table_k_budget
    from repro.data.generators import subgen_queries

    g = subgen_like_graph(n_nodes=150, n_edges=420, n_embed=8, seed=5)
    wl = Workload("Tiny", g, subgen_queries(g))
    sweep = run_budget_sweep([wl], heuristics=(MAX_SN,), ks=(1, None),
                             seed=0, cap=16384)
    assert sweep.stats
    for s in sweep.stats:
        assert s.answers_requested in (1, None)
        assert s.loads_saved_vs_full >= 0
        if s.answers_requested is None:
            assert s.loads_saved_vs_full == 0
    table = table_k_budget(sweep, str(tmp_path))
    assert "K=1" in table and "K=inf" in table and "MAX-SN" in table
    assert (tmp_path / "table_k_budget.csv").exists()
