"""OPAT / TraditionalMP / MapReduceMP vs the whole-graph oracle
(paper correctness claims, Sec. 4.2 / 7 / 8 / 9)."""
import numpy as np
import pytest


from repro.core import (ALL_HEURISTICS, EngineConfig, MAX_SN, OPATEngine, TraditionalMPEngine,
                        build_catalog, build_partitions, generate_plan, match_query,
                        partition_graph)
from repro.core.mapreduce_mp import MapReduceMPEngine
from repro.data.generators import (imdb_like_graph, imdb_queries,
                                   subgen_like_graph, subgen_queries)


def _ref(graph, query, q_pad=8):
    return match_query(graph, query, q_pad=q_pad)


@pytest.fixture(scope="module")
def setup():
    g = subgen_like_graph(n_nodes=250, n_edges=700, n_embed=10, seed=3)
    assign = partition_graph(g, 4, "kway_shem")
    pg = build_partitions(g, assign, 4)
    cat = build_catalog(g)
    queries = [dq.disjuncts[0] for dq in subgen_queries(g)]
    return g, pg, cat, queries


@pytest.mark.parametrize("heuristic", ALL_HEURISTICS)
def test_opat_matches_oracle_all_heuristics(setup, heuristic):
    g, pg, cat, queries = setup
    eng = OPATEngine(pg, EngineConfig(cap=16384))
    for q in queries:
        plan = generate_plan(q, g, cat)
        res = eng.run(plan, heuristic, seed=1)
        assert np.array_equal(np.unique(res.answers, axis=0), _ref(g, q)), \
            (q.name, heuristic)


def test_opat_load_ratio_in_range(setup):
    g, pg, cat, queries = setup
    eng = OPATEngine(pg, EngineConfig(cap=16384))
    for q in queries:
        plan = generate_plan(q, g, cat)
        res = eng.run(plan, MAX_SN)
        assert 1 <= res.stats.l_ideal <= pg.k
        if res.answers.shape[0]:
            assert 0 < res.stats.load_ratio <= 1.0


@pytest.mark.parametrize("p", [1, 2, 4, 6])
def test_traditional_mp_matches_oracle(setup, p):
    g, pg, cat, queries = setup
    eng = TraditionalMPEngine(pg, p, EngineConfig(cap=16384))
    for q in queries:
        plan = generate_plan(q, g, cat)
        res = eng.run(plan, MAX_SN, seed=1)
        assert np.array_equal(np.unique(res.answers, axis=0), _ref(g, q))
        # p processors -> each iteration uses at most p partitions
        assert all(len(it) <= p for it in res.partitions_per_iteration)


def test_traditional_mp_fewer_iterations_than_opat(setup):
    """More processors should never need MORE iterations (paper Sec. 8.2)."""
    g, pg, cat, queries = setup
    e1 = TraditionalMPEngine(pg, 1, EngineConfig(cap=16384))
    e4 = TraditionalMPEngine(pg, 4, EngineConfig(cap=16384))
    for q in queries:
        plan = generate_plan(q, g, cat)
        i1 = e1.run(plan, MAX_SN, seed=1).stats.iterations
        i4 = e4.run(plan, MAX_SN, seed=1).stats.iterations
        assert i4 <= i1


def test_mapreduce_single_device_matches_oracle(setup):
    g, pg_4, cat, queries = setup
    # one partition per device; this container has 1 device -> k=1
    pg = build_partitions(g, np.zeros(g.n_nodes, dtype=np.int32), 1)
    from repro.compat import make_part_mesh
    mesh = make_part_mesh(1)
    eng = MapReduceMPEngine(pg, mesh, EngineConfig(cap=32768))
    for q in queries:
        plan = generate_plan(q, g, cat)
        res = eng.run(plan)
        assert np.array_equal(np.unique(res.answers, axis=0), _ref(g, q))
        # one-edge-at-a-time: iterations >= max plan path length (Sec. 9)
        assert res.n_iterations >= plan.max_path_len()


def test_same_partition_needed_twice(small_graph):
    """Fig. 4c: answers that re-enter an already-processed partition."""
    # force a 2-partition split of a path that zig-zags across partitions
    from repro.core.graph import GraphBuilder
    b = GraphBuilder()
    n0 = b.add_node("S")
    n1 = b.add_node("T")
    n2 = b.add_node("U")
    n3 = b.add_node("V")
    b.add_edge(n0, n1, "e")
    b.add_edge(n1, n2, "e")
    b.add_edge(n2, n3, "e")
    g = b.build()
    assign = np.array([0, 1, 0, 1], dtype=np.int32)  # zig-zag
    pg = build_partitions(g, assign, 2)
    cat = build_catalog(g)
    from repro.core.query import Query, QueryEdge, QueryNode
    q = Query(nodes=[QueryNode("S"), QueryNode("T"), QueryNode("U"),
                     QueryNode("V")],
              edges=[QueryEdge(0, 1, "e"), QueryEdge(1, 2, "e"),
                     QueryEdge(2, 3, "e")])
    plan = generate_plan(q, g, cat, start_slot=0)
    eng = OPATEngine(pg, EngineConfig(cap=256))
    res = eng.run(plan, MAX_SN)
    assert res.answers.shape[0] == 1
    # partition 0 (and 1) must appear more than once in the load sequence
    loads = res.stats.loads
    assert max(loads.count(0), loads.count(1)) >= 2


def test_imdb_disjunctive_queries():
    g = imdb_like_graph(n_movies=120, n_people=150, seed=7)
    assign = partition_graph(g, 4, "ecosocial")
    pg = build_partitions(g, assign, 4)
    cat = build_catalog(g)
    eng = OPATEngine(pg, EngineConfig(cap=16384))
    from repro.core.oracle import match_disjunctive
    for dq in imdb_queries(g, seed=7):
        got = None
        for q in dq.disjuncts:
            plan = generate_plan(q, g, cat)
            res = eng.run(plan, MAX_SN)
            a = res.answers
            got = a if got is None else np.unique(np.concatenate([got, a]), axis=0)
        ref = match_disjunctive(g, dq, q_pad=8)
        assert got.shape[0] == ref.shape[0]
        if ref.shape[0]:
            assert np.array_equal(np.unique(got, axis=0), ref)


def test_overflow_raises(setup):
    g, pg, cat, queries = setup
    from repro.core.query import Query, QueryEdge, QueryNode
    # all-wildcard 2-path: thousands of embeddings >> cap
    q = Query(nodes=[QueryNode("?")] * 3,
              edges=[QueryEdge(0, 1, "?"), QueryEdge(1, 2, "?")])
    eng = OPATEngine(pg, EngineConfig(cap=8))   # absurdly small buffers
    plan = generate_plan(q, g, cat)
    with pytest.raises(RuntimeError):
        eng.run(plan, MAX_SN)
