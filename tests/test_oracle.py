"""Ground-truth matcher semantics on hand-built graphs with known answers."""

from repro.core.graph import GraphBuilder
from repro.core.oracle import match_query
from repro.core.query import (Query, QueryEdge, QueryNode, QDIR_IN, QDIR_OUT)


def build_path_graph():
    b = GraphBuilder()
    a = b.add_node("A")
    x = b.add_node("B")
    y = b.add_node("B")
    c = b.add_node("C")
    b.add_edge(a, x, "e")
    b.add_edge(a, y, "e")
    b.add_edge(x, c, "f")
    return b.build(), (a, x, y, c)


def test_path_two_embeddings():
    g, (a, x, y, c) = build_path_graph()
    q = Query(nodes=[QueryNode("A"), QueryNode("B")],
              edges=[QueryEdge(0, 1, "e")])
    res = match_query(g, q)
    assert res.shape[0] == 2
    assert {tuple(r) for r in res.tolist()} == {(a, x), (a, y)}


def test_edge_label_filters():
    g, (a, x, y, c) = build_path_graph()
    q = Query(nodes=[QueryNode("B"), QueryNode("C")],
              edges=[QueryEdge(0, 1, "f")])
    res = match_query(g, q)
    assert res.shape[0] == 1 and tuple(res[0]) == (x, c)


def test_direction_semantics():
    b = GraphBuilder()
    u = b.add_node("U")
    v = b.add_node("V")
    b.add_edge(u, v, "d", directed=True)
    g = b.build()
    q_out = Query(nodes=[QueryNode("U"), QueryNode("V")],
                  edges=[QueryEdge(0, 1, "d", direction=QDIR_OUT)])
    q_in = Query(nodes=[QueryNode("U"), QueryNode("V")],
                 edges=[QueryEdge(0, 1, "d", direction=QDIR_IN)])
    assert match_query(g, q_out).shape[0] == 1
    assert match_query(g, q_in).shape[0] == 0


def test_value_predicates():
    b = GraphBuilder()
    m = b.add_node("M")
    y1 = b.add_node("year", value=1999.0)
    y2 = b.add_node("year", value=2005.0)
    b.add_edge(m, y1, "in")
    b.add_edge(m, y2, "in")
    g = b.build()
    for op, val, expect in [("!=", 1999.0, 1), ("=", 1999.0, 1),
                            ("<", 2000.0, 1), (">=", 1999.0, 2),
                            (">", 2005.0, 0)]:
        q = Query(nodes=[QueryNode("M"),
                         QueryNode("year", value_op=op, value=val)],
                  edges=[QueryEdge(0, 1, "in")])
        assert match_query(g, q).shape[0] == expect, (op, val)


def test_nan_value_fails_all_predicates():
    b = GraphBuilder()
    m = b.add_node("M")
    y = b.add_node("year")          # no value
    b.add_edge(m, y, "in")
    g = b.build()
    q = Query(nodes=[QueryNode("M"), QueryNode("year", value_op="!=", value=0.0)],
              edges=[QueryEdge(0, 1, "in")])
    assert match_query(g, q).shape[0] == 0


def test_injectivity():
    """Subgraph isomorphism: one node can't bind two slots."""
    b = GraphBuilder()
    a = b.add_node("A")
    c = b.add_node("A")
    b.add_edge(a, c, "e")
    g = b.build()
    q = Query(nodes=[QueryNode("A"), QueryNode("A"), QueryNode("A")],
              edges=[QueryEdge(0, 1, "e"), QueryEdge(1, 2, "e")])
    assert match_query(g, q).shape[0] == 0


def test_cycle_query():
    b = GraphBuilder()
    n = [b.add_node("T") for _ in range(3)]
    b.add_edge(n[0], n[1], "e")
    b.add_edge(n[1], n[2], "e")
    b.add_edge(n[2], n[0], "e")
    b.add_edge(n[0], b.add_node("T"), "e")  # a dangling extra
    g = b.build()
    q = Query(nodes=[QueryNode("T")] * 3,
              edges=[QueryEdge(0, 1, "e"), QueryEdge(1, 2, "e"),
                     QueryEdge(2, 0, "e")])
    res = match_query(g, q)
    assert res.shape[0] == 6  # 3! automorphic embeddings of the triangle


def test_wildcard_label():
    g, (a, x, y, c) = build_path_graph()
    q = Query(nodes=[QueryNode("?"), QueryNode("C")],
              edges=[QueryEdge(0, 1, "?")])
    assert match_query(g, q).shape[0] == 1
