"""Checkpoint save/restore: atomicity, pruning, pipeline-state restarts."""
import json
import os

import numpy as np

import jax.numpy as jnp

from repro.data.tokens import TokenPipeline
from repro.distributed import (CheckpointManager, latest_step,
                               load_checkpoint, save_checkpoint)


def make_state(x=1.0):
    return {"params": {"w": jnp.full((4, 4), x), "layers": [
        {"a": jnp.arange(3, dtype=jnp.float32) * x}]},
        "opt": {"step": jnp.int32(7 * x)}}


def test_roundtrip(tmp_path):
    d = str(tmp_path)
    state = make_state(2.0)
    save_checkpoint(d, 10, state)
    step, restored, meta = load_checkpoint(d, make_state(0.0))
    assert step == 10 and meta["step"] == 10
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["layers"][0]["a"]),
        np.asarray(state["params"]["layers"][0]["a"]))


def test_latest_and_prune(tmp_path):
    d = str(tmp_path)
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(d, s, make_state(float(s)), keep=2)
    assert latest_step(d) == 5
    kept = sorted(int(n.split("_")[1]) for n in os.listdir(d)
                  if n.startswith("step_"))
    assert kept == [4, 5]


def test_uncommitted_checkpoint_ignored(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 3, make_state())
    # fake a torn write: step dir without the done marker
    torn = os.path.join(d, "step_00000009")
    os.makedirs(torn)
    with open(os.path.join(torn, "meta.json"), "w") as f:
        json.dump({"step": 9}, f)
    assert latest_step(d) == 3


def test_manager_every(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=5)
    st = make_state()
    assert mgr.maybe_save(3, st) is None
    assert mgr.maybe_save(5, st) is not None
    assert mgr.restore_or_none(make_state(0.0)) is not None


def test_pipeline_state_restart():
    p1 = TokenPipeline(vocab=64, batch=2, seq=16, seed=9)
    batches = [p1.next_batch() for _ in range(5)]
    state = p1.state_dict()
    p2 = TokenPipeline(vocab=64, batch=2, seq=16, seed=9)
    p2.load_state_dict(state)
    nxt1 = p1.next_batch()
    nxt2 = p2.next_batch()
    np.testing.assert_array_equal(nxt1["tokens"], nxt2["tokens"])
    # determinism: batch i is a pure function of (seed, i)
    np.testing.assert_array_equal(
        batches[2]["tokens"],
        TokenPipeline(vocab=64, batch=2, seq=16, seed=9).batch_at(2)["tokens"])
    # labels are next-token targets
    np.testing.assert_array_equal(batches[0]["tokens"][:, 1:],
                                  batches[0]["labels"][:, :-1])
