"""Sharding rule resolver: divisibility fallbacks and spec structure.

The production meshes need 256/512 devices, so resolver logic is tested
against a lightweight fake mesh (resolve() only reads axis_names/shape);
NamedSharding construction is tested on the real 1-device mesh.
"""

import numpy as np

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, reduced
from repro.launch.mesh import make_test_mesh
from repro.launch.sharding import AXES_BY_NAME, ShardingRules, param_shardings, batch_shardings
from repro.models.transformer import abstract_params


class FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


def rules():
    return ShardingRules(FakeMesh())


def test_divisible_dims_sharded():
    spec = rules().resolve((8192, 64, 128), ("embed", "heads", None))
    assert spec == P("data", "model")


def test_non_divisible_heads_fall_back():
    # qwen2-1.5b: 12 heads % 16 != 0 -> heads replicated, embed still sharded
    spec = rules().resolve((1536, 12, 128), ("embed", "heads", None))
    assert spec == P("data")


def test_kv_heads_replicated_when_small():
    spec = rules().resolve((8192, 8, 128), ("embed", "kv_heads", None))
    assert spec == P("data")


def test_axis_never_reused():
    # [d, d] with both dims wanting 'data' -> second falls back to None
    spec = rules().resolve((2048, 2048), ("embed", "embed"))
    assert spec == P("data")


def test_odd_vocab_replicated():
    spec = rules().resolve((49155, 2048), ("vocab", "embed"))
    assert spec == P(None, "data")


def test_experts_shard_over_model():
    spec = rules().resolve((64, 2048, 1408), ("experts", "embed", None))
    assert spec == P("model", "data")


def test_stacked_leading_dim_gets_none():
    spec = rules().resolve((28, 2048, 8192), ("embed", "mlp"))
    assert spec == P(None, "data", "model")


def test_all_param_leaves_have_rules():
    """Every leaf name in every arch's param tree must be covered by
    AXES_BY_NAME (falls back to replicated otherwise — catch typos)."""
    for aid, cfg in ARCHS.items():
        abs_p = abstract_params(reduced(cfg))
        flat = jax.tree_util.tree_flatten_with_path(abs_p)[0]
        for path, leaf in flat:
            name = str(path[-1].key if hasattr(path[-1], "key") else path[-1])
            assert name in AXES_BY_NAME, (aid, name)


def test_param_shardings_on_real_mesh():
    mesh = make_test_mesh((1, 1))
    cfg = reduced(ARCHS["qwen3_4b"])
    sh = param_shardings(cfg, mesh)
    abs_p = abstract_params(cfg)
    # structurally identical trees
    assert (jax.tree_util.tree_structure(sh)
            == jax.tree_util.tree_structure(abs_p))


def test_batch_shardings_scalar_and_arrays():
    mesh = make_test_mesh((1, 1))
    tree = {"tokens": jax.ShapeDtypeStruct((8, 64), np.int32),
            "pos": jax.ShapeDtypeStruct((), np.int32)}
    sh = batch_shardings(mesh, tree)
    assert sh["pos"].spec == P()


def test_cache_shardings_kv_seq_axis():
    fm = FakeMesh()
    r = ShardingRules(fm)
    # emulate what cache_shardings computes for a [B,S,H,hd] leaf
    spec = r.resolve((128, 32768, 8, 128), (None, None, "kv_heads", None))
    # resolver alone won't shard S; cache_shardings adds model on S:
    from repro.launch.sharding import _batch_dim_spec
    assert _batch_dim_spec(fm, 128) == "data"
    assert _batch_dim_spec(fm, 1) is None
    assert 32768 % fm.shape["model"] == 0
