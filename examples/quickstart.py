"""Quickstart: open a GraphSession on a partitioned movie graph, serve
expressive queries against it, check the whole-graph oracle, and round
the graph through disk (save -> open -> query, the out-of-core path).

A ``GraphSession`` (core/session.py) is the serving API: built once from
(graph, scheme, k, engine), it compiles the partition evaluator, stages
partitions into a device-resident ``PartitionStore``, and then answers
repeated ``submit`` calls.  The first query pays *cold* partition loads
(host->device transfers); repeats find them *warm* (device-resident) —
the paper's response-time story made explicit.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
sys.path.insert(0, "src")

import numpy as np

from repro.core import GraphSession, match_query
from repro.core.query import Query, QueryEdge, QueryNode
from repro.data.generators import imdb_like_graph

# 1. a movie graph (IMDB-like: unique people/movies, typed edges)
graph = imdb_like_graph(n_movies=200, n_people=250, seed=42)
print(f"graph: {graph.n_nodes} nodes, {graph.n_edges} edges")

# 2. one session = one partitioned graph + one engine compile + a shared
#    partition cache, serving many queries (multilevel kway + sorted
#    heavy-edge matching partitioner, METIS-style)
session = GraphSession(graph, k=4, scheme="kway_shem", engine="opat")
print(f"session: k={session.k} scheme={session.scheme} "
      f"cut = {session.pg.cut_edges} edges")

# 3. an expressive query: movies by person_7, their genre and production
#    company, released after 1999 (comparison operator on a node value)
query = Query(name="demo", nodes=[
    QueryNode("person_7"),                                  # 0
    QueryNode("?"),                                         # 1 movie (wildcard)
    QueryNode("?"),                                         # 2 company
    QueryNode("year", value_op=">", value=1999.0),          # 3
], edges=[
    QueryEdge(0, 1, "acted_in"),
    QueryEdge(1, 2, "produced_by"),
    QueryEdge(1, 3, "in_year"),
])

# 4. serve it: the session plans the query (QP-Subdue cost-based) and runs
#    OPAT with MAX-SN.  Every partition load is cold — nothing was
#    device-resident yet — and while each partition evaluates, the
#    heuristic's runner-up is prefetched in the background.
res = session.submit(query)
stats = res.stats[0]
print(f"answers: {res.n_answers}; partition loads {stats.loads} "
      f"(L_ideal={stats.l_ideal}, ratio={stats.load_ratio:.2f}); "
      f"cold={res.load_stats.cold_loads} warm={res.load_stats.warm_loads}")

# 5. verify against the independent whole-graph matcher
ref = match_query(graph, query, q_pad=8)
assert np.array_equal(res.answers, ref)
print("oracle check: MATCH")

# 6. serve it AGAIN: the session's PartitionStore still holds every
#    partition, so the repeat pays zero cold transfers — warm loads only
again = session.submit(query)
assert np.array_equal(again.answers, ref)
print(f"warm repeat: cold={again.load_stats.cold_loads} "
      f"warm={again.load_stats.warm_loads} "
      f"(latency {again.latency_s*1000:.0f} ms vs first "
      f"{res.latency_s*1000:.0f} ms)")
assert again.load_stats.cold_loads == 0

# 7. answer budget: ask for the FIRST answer only ("all or specified number
#    of answers") — the engine stops loading partitions as soon as one
#    unique answer exists, which is the low-response-time serving mode
top1 = session.submit(query, max_answers=1)
print(f"top-1: {top1.n_answers} answer in {top1.stats[0].n_loads} loads "
      f"(full run took {stats.n_loads})")
assert tuple(top1.answers[0]) in {tuple(r) for r in ref}

# 8. a BATCH of concurrent queries: submit_many routes them through the
#    shared-load QueryScheduler (docs/scheduler.md) — every partition load
#    advances all queries waiting on it in one batched compiled call, and
#    each query retires on its own budget
batch = [Query(name=f"demo{i}", nodes=query.nodes, edges=query.edges)
         for i in range(4)]
report = session.submit_many(batch, max_answers=2)
print(f"batch: {len(report.results)} queries in {report.n_loads} workload "
      f"loads ({report.loads_per_query:.2f}/query, batch sizes "
      f"{report.batch_sizes})")
assert all(r.n_answers == min(2, ref.shape[0]) for r in report.results)

# 9. the session remembers what it served: a per-partition workload profile
#    (loads / completed / spawned / completion rate) that a workload-aware
#    repartitioner can consume, persisted as JSON via save_profile(path)
prof = session.workload_profile()
print(f"profile: {prof['queries_served']} queries, cache hit rate "
      f"{prof['cache']['hit_rate']:.0%}, per-partition loads "
      f"{[p['loads'] for p in prof['partitions']]}")

# 10. out-of-core round trip: save the partitioned graph as a directory of
#     per-partition shards (+ manifest), reopen it with a host cache too
#     small to hold them all, and serve the same query straight off disk —
#     the store's three-tier cache (disk -> pinned host LRU -> device LRU)
#     pays shard reads and overlaps them with background read-ahead, at
#     answers identical to the in-RAM session (docs/storage.md)
import tempfile

with tempfile.TemporaryDirectory(prefix="quickstart-graph-") as gdir:
    manifest = session.save(gdir)
    shard_bytes = sum(p["nbytes"] for p in manifest["partitions"])
    disk_session = GraphSession.open(gdir, engine="opat",
                                     cache_parts=2, host_cache_parts=2)
    ooc = disk_session.submit(query)
    assert np.array_equal(ooc.answers, ref)
    st = disk_session.load_stats
    print(f"out of core: {shard_bytes} shard bytes behind a 2-partition "
          f"host cache -> same {ooc.n_answers} answers, "
          f"{st.disk_reads} disk reads "
          f"({st.read_ahead_hits} served by read-ahead)")
    assert st.disk_reads > 0
