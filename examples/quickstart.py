"""Quickstart: partition a graph, run an expressive query with OPAT, check
against the whole-graph oracle.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
sys.path.insert(0, "src")

import numpy as np

from repro.core import (EngineConfig, MAX_SN, OPATEngine, RunRequest,
                        build_catalog, build_partitions, generate_plan,
                        match_query, partition_graph)
from repro.core.query import Query, QueryEdge, QueryNode
from repro.data.generators import imdb_like_graph

# 1. a movie graph (IMDB-like: unique people/movies, typed edges)
graph = imdb_like_graph(n_movies=200, n_people=250, seed=42)
print(f"graph: {graph.n_nodes} nodes, {graph.n_edges} edges")

# 2. partition it (multilevel kway + sorted heavy-edge matching, METIS-style)
k = 4
assign = partition_graph(graph, k, "kway_shem")
pg = build_partitions(graph, assign, k)
print(f"partitioned into {k}: cut = {pg.cut_edges} edges")

# 3. an expressive query: movies by person_7, their genre and production
#    company, released after 1999 (comparison operator on a node value)
query = Query(name="demo", nodes=[
    QueryNode("person_7"),                                  # 0
    QueryNode("?"),                                         # 1 movie (wildcard)
    QueryNode("?"),                                         # 2 company
    QueryNode("year", value_op=">", value=1999.0),          # 3
], edges=[
    QueryEdge(0, 1, "acted_in"),
    QueryEdge(1, 2, "produced_by"),
    QueryEdge(1, 3, "in_year"),
])

# 4. cost-based plan (QP-Subdue style) + OPAT evaluation with MAX-SN
catalog = build_catalog(graph)
plan = generate_plan(query, graph, catalog)
print(f"plan: start slot {plan.start_slot}, {plan.n_steps} steps, "
      f"est cost {plan.est_cost:.1f}")

engine = OPATEngine(pg, EngineConfig(cap=16384))
res = engine.run(plan, MAX_SN)
print(f"answers: {res.answers.shape[0]}; partition loads {res.stats.loads} "
      f"(L_ideal={res.stats.l_ideal}, ratio={res.stats.load_ratio:.2f})")

# 5. verify against the independent whole-graph matcher
ref = match_query(graph, query, q_pad=8)
assert np.array_equal(np.unique(res.answers, axis=0), ref)
print("oracle check: MATCH")

# 6. answer budget: ask for the FIRST answer only ("all or specified number
#    of answers") — the engine stops loading partitions as soon as one
#    unique answer exists, which is the low-response-time serving mode
rep = engine.run_request(RunRequest(plan=plan, heuristic=MAX_SN,
                                    max_answers=1))
print(f"top-1: {rep.answers.shape[0]} answer in {rep.stats.n_loads} loads "
      f"(full run took {res.stats.n_loads})")
assert tuple(rep.answers[0]) in {tuple(r) for r in ref}
