"""End-to-end driver (the paper's kind: query serving): batched queries on a
partitioned graph with all three engines and the paper's metrics, served
through one GraphSession (shared partition cache, cold/warm load split).

    PYTHONPATH=src python examples/serve_queries.py
    PYTHONPATH=src python examples/serve_queries.py --engine traditional -p 4
    PYTHONPATH=src python examples/serve_queries.py --cache-parts 2 \
        --max-answers 5 --json report.json
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/serve_queries.py --engine mapreduce

Delegates to repro.launch.serve (the real launcher) with demo defaults.
"""
import sys
sys.path.insert(0, "src")

if __name__ == "__main__":
    from repro.launch.serve import main
    if len(sys.argv) == 1:
        sys.argv += ["--dataset", "synthetic", "--scale", "1.0", "--k", "4",
                     "--scheme", "ecosocial", "--engine", "opat",
                     "--heuristic", "max-sn", "--verify"]
    # map -p to --processors for convenience
    sys.argv = [a if a != "-p" else "--processors" for a in sys.argv]
    main()
