"""LM-substrate demo: train a reduced qwen2-1.5b for 200 steps on the
synthetic Markov token pipeline, with checkpoints — kill and rerun to watch
it resume.  Loss drops from ~4.9 (uniform) toward the source entropy.

    PYTHONPATH=src python examples/train_lm.py
"""
import sys
sys.path.insert(0, "src")

if __name__ == "__main__":
    from repro.launch.train import main
    if len(sys.argv) == 1:
        sys.argv += ["--arch", "qwen2-1.5b", "--smoke", "--steps", "200",
                     "--batch", "16", "--seq", "128", "--lr", "1e-3",
                     "--ckpt-dir", "/tmp/repro_train_lm", "--ckpt-every", "50"]
    main()
