"""MapReduceMP demo: the paper's Sec. 9 algorithm as ONE SPMD program —
4 mapper devices (one partition each), quota-based all_to_all shuffle,
global-psum stop test.  Sets its own device count, so run it directly:

    PYTHONPATH=src python examples/mapreduce_demo.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import sys
sys.path.insert(0, "src")

import numpy as np
import jax

from repro.core import (EngineConfig, MAX_SN, build_catalog,
                        build_partitions, generate_plan, match_query,
                        partition_graph)
from repro.core.mapreduce_mp import MapReduceMPEngine
from repro.data.generators import subgen_like_graph, subgen_queries

graph = subgen_like_graph(n_nodes=1000, n_edges=3000, n_embed=30, seed=1)
k = 4
assign = partition_graph(graph, k, "ecosocial")
pg = build_partitions(graph, assign, k)
catalog = build_catalog(graph)
from repro.compat import make_part_mesh
mesh = make_part_mesh(k)
print(f"graph {graph.n_nodes}/{graph.n_edges}; {k} partitions on "
      f"{jax.device_count()} devices")

engine = MapReduceMPEngine(pg, mesh, EngineConfig(cap=32768))
for dq in subgen_queries(graph):
    q = dq.disjuncts[0]
    plan = generate_plan(q, graph, catalog)
    res = engine.run(plan)
    ref = match_query(graph, q, q_pad=8)
    ok = np.array_equal(np.unique(res.answers, axis=0), ref)
    print(f"{q.name}: {res.answers.shape[0]} answers in "
          f"{res.n_iterations} map/reduce iterations "
          f"(plan max path {plan.max_path_len()}) — "
          f"{'MATCH' if ok else 'MISMATCH'} vs oracle")
